"""Paper Fig. 3: layer-wise distribution of selected parameters for ResNet
and ViT — demonstrates the back-end concentration that motivates CAU/BD."""
from __future__ import annotations

from repro.api import ForgetRequest, UnlearnSpec, Unlearner
from repro.data import synthetic as syn

from . import common


def run(models=("resnet", "vit"), forget_class: int = 2) -> dict:
    out = {}
    for model in models:
        s = common.trained(model)
        alpha, lam = common.HPARAMS[model]
        splits = syn.split_forget_retain(s["x"], s["y"], forget_class)
        fx, fy = splits["forget"]
        unl = Unlearner(s["adapter"], s["I_D"],
                        UnlearnSpec.for_mode("ssd", alpha=alpha, lam=lam))
        _, st = unl.forget(ForgetRequest(fx[:32], fy[:32]),
                           params=s["params"])
        out[model] = st["selected_per_layer"]
    return out


def main() -> dict:
    res = run()
    print("# Fig. 3 — selected parameters per layer (l=1 is the back-end)")
    for model, sel in res.items():
        total = sum(sel.values()) or 1
        print(f"\n{model}:")
        for l in sorted(sel):
            frac = sel[l] / total
            bar = "#" * int(frac * 60)
            print(f"  l={l:2d}  {sel[l]:7d}  {frac * 100:5.1f}% {bar}")
        back = sum(v for l, v in sel.items() if l <= len(sel) // 2)
        print(f"  back-end half share: {100.0 * back / total:.1f}%")
        print(f"fig3_selection,{model},0,backend_share="
              f"{100.0 * back / total:.1f}")
    return res


if __name__ == "__main__":
    main()
