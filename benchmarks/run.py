"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1     # one
    PYTHONPATH=src python -m benchmarks.run --smoke    # quick CI subset

Each benchmark prints its human-readable table followed by CSV lines
``name,us_per_call,derived``.
"""
from __future__ import annotations

import sys
import time

# jobs quick enough for the CI smoke lane (no model training required).
# serve_latency MERGES into BENCH_serve.json, which kernels_bench's
# serve_bench overwrites — keep it after "kernels" in the order.
SMOKE_JOBS = ("kernels", "compression", "load", "serve_latency")


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    t0 = time.time()
    from . import (compression_bench, fig3_selection, kernels_bench,
                   load_bench, roofline_report, serve_latency_bench,
                   table1_cau, table2_bd, table4_e2e)

    jobs = {
        "table1": table1_cau.main,
        "table2": table2_bd.main,
        "table4": table4_e2e.main,
        "fig3": fig3_selection.main,
        "kernels": kernels_bench.main,
        "compression": compression_bench.main,
        "load": load_bench.main,
        "serve_latency": serve_latency_bench.main,
        "roofline": roofline_report.main,
    }
    if which == "--smoke":
        jobs = {k: jobs[k] for k in SMOKE_JOBS}
    elif which != "all":
        jobs = {which: jobs[which]}
    failed = []
    for name, fn in jobs.items():
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            print(f"{name},FAILED,0,error={e!r}")
            failed.append(name)
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s"
          + (f", FAILED: {failed}" if failed else ""))
    if failed:  # CI must not treat a crashed benchmark as a quiet pass
        raise SystemExit(1)


if __name__ == "__main__":
    main()
