"""Serve-latency benchmark: decode-step tail latency WITH mid-stream
drains vs drain-free (the zero-downtime claim of DESIGN.md §15).

The legacy batch loop stalls every in-flight request for the full sweep
latency at each drain point.  The stream engine runs the sweep on a
worker thread against the tenant's SHADOW tree and publishes at a step
deadline with an atomic pointer swap, so the decode loop never waits for
unlearning.  What we measure:

  * per-engine-step wall time of a ``StreamEngine`` serving ``R_SEQ``
    fixed-length sequences over an 8-slot pool, steady state (decode,
    admission and eviction are all dispatched WITHOUT host syncs; the
    tail comes from JAX's in-flight-queue back-pressure, present in both
    variants);
  * the same workload with two shadow drains fired mid-stream — the
    sweep smears into the cheap dispatch steps, so p99 must stay within
    20% of drain-free (``serve_stream_p99_ratio``, gated in
    benchmarks/check_regression.py);
  * determinism: the with-drains variant runs TWICE and must produce
    identical engine-side event streams (admit/evict/fire/publish,
    canonicalized) — ``serve_stream_deterministic``, gated at 1.

Merged into BENCH_serve.json (kernels_bench's serve_bench writes the
file first; this job must run after it in benchmarks/run.py).
"""
from __future__ import annotations

import jax
import numpy as np

from .kernels_bench import BENCH_SERVE_PATH, _merge_bench_json

ARCH = "gemma3-1b"
P_LEN, G_LEN = 16, 32
MAX_BATCH, ADMIT_CHUNK = 8, 4
R_SEQ = 500
WARM_SEQ = 16
DRAIN_STEPS = (600, 1200)
DRAIN_DOMAIN = 1          # both drains share one sweep signature
PUBLISH_LAG = 150         # > the sweep's step span: deadlines rarely block


def _build(programs):
    from repro import configs
    from repro.api import ServeSpec
    from repro.data import synthetic as syn
    from repro.launch.serve import ForgetService, StreamEngine
    from repro.models import lm as LM

    cfg = configs.get(ARCH).smoke
    seq_len = P_LEN + G_LEN
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=seq_len,
                            n_per_domain=16, seed=0)
    toks, doms = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    svc = ForgetService(cfg, toks, doms, seq_len, programs=programs,
                        serve=ServeSpec(publish="step",
                                        max_batch=MAX_BATCH,
                                        admit_chunk=ADMIT_CHUNK,
                                        publish_lag=PUBLISH_LAG))
    eng = StreamEngine(params, cfg, gen_len=G_LEN, prompt_len=P_LEN,
                       max_batch=MAX_BATCH, admit_chunk=ADMIT_CHUNK,
                       publish_lag=PUBLISH_LAG, service=svc)
    prompts = np.asarray(toks[:, :P_LEN])
    return svc, eng, prompts


def _percentile(sorted_vals, q):
    i = min(int(round(q * (len(sorted_vals) - 1))), len(sorted_vals) - 1)
    return sorted_vals[i]


def _run_variant(with_drains: bool, programs) -> dict:
    from repro.obs import telemetry as _t

    svc, eng, prompts = _build(programs)
    # warm every program BEFORE measuring: prefill/decode/admit via a
    # short stream, and (with drains) the sweep signature via a discarded
    # shadow drain — the measured window then exercises only warm replays
    for i in range(WARM_SEQ):
        eng.enqueue(10_000_000 + i, prompts[i % len(prompts)])
    eng.run()
    eng.results.clear()
    if with_drains:
        svc.run_shadow([DRAIN_DOMAIN], 0)
        svc.discard_shadow()
    eng.step_wall.clear()

    if with_drains:
        for due in DRAIN_STEPS:
            svc.submit(DRAIN_DOMAIN, due_batch=due)
    for i in range(R_SEQ):
        eng.enqueue(i, prompts[i % len(prompts)])
    with _t.capture() as cap:
        out = eng.run()
    if len(out) != R_SEQ:
        raise RuntimeError(f"stream served {len(out)}/{R_SEQ} sequences")
    from repro.launch.serve import engine_fingerprint

    lat = sorted(eng.step_wall)
    fp = engine_fingerprint(cap.events)
    return {"p50_ms": _percentile(lat, 0.50) * 1e3,
            "p99_ms": _percentile(lat, 0.99) * 1e3,
            "steps": len(lat),
            "publications": eng.publications,
            "decode_signatures": eng.decode_cache_size(),
            "fingerprint": fp}


def main() -> dict:
    from repro.engine import ProgramCache

    # ONE shared program cache across the three runs: the sweep family
    # compiles once (cold) in the first with-drains warmup and replays
    # warm everywhere else — exactly the serving steady state
    programs = ProgramCache()
    free = _run_variant(False, programs)
    drained = _run_variant(True, programs)
    repeat = _run_variant(True, programs)

    deterministic = int(drained["fingerprint"] == repeat["fingerprint"])
    ratio = drained["p99_ms"] / free["p99_ms"]
    out = {
        "serve_stream_config": (
            f"{ARCH}-smoke stream: pool {MAX_BATCH}, admit {ADMIT_CHUNK}, "
            f"{R_SEQ} seqs x {P_LEN}+{G_LEN} tokens, drains at steps "
            f"{list(DRAIN_STEPS)}, publish_lag {PUBLISH_LAG}"),
        "decode_p50_drain_free": free["p50_ms"],
        "decode_p99_drain_free": free["p99_ms"],
        "decode_p50_with_drains": drained["p50_ms"],
        "decode_p99_with_drains": drained["p99_ms"],
        "serve_stream_p99_ratio": ratio,
        "serve_stream_steps": drained["steps"],
        "serve_stream_publications": drained["publications"],
        "serve_stream_decode_signatures": drained["decode_signatures"],
        "serve_stream_deterministic": deterministic,
        "serve_stream_fingerprint": drained["fingerprint"],
    }
    _merge_bench_json(BENCH_SERVE_PATH, out)

    print(f"\nserve latency (per engine step, {drained['steps']} steps):")
    print(f"  drain-free   p50 {free['p50_ms']:8.3f} ms   "
          f"p99 {free['p99_ms']:8.3f} ms")
    print(f"  with drains  p50 {drained['p50_ms']:8.3f} ms   "
          f"p99 {drained['p99_ms']:8.3f} ms   "
          f"({drained['publications']} publication(s))")
    print(f"  p99 ratio {ratio:.3f}  deterministic={deterministic}  "
          f"decode signatures={drained['decode_signatures']}")
    print(f"serve_stream,p99_ratio,{ratio:.4f},"
          f"deterministic={deterministic}")
    return out


if __name__ == "__main__":
    main()
