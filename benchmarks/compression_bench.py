"""Gradient-compression benchmark: wire-byte reduction for the DP all-reduce
path and the numerical error after error feedback — the collective-term
lever for the roofline (§Perf)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.optim import Int8Codec, TopKCodec

N = 1 << 20


def main() -> dict:
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=N), jnp.float32)}
    out = {}
    for name, codec in (("int8", Int8Codec(block=256)),
                        ("topk1pct", TopKCodec(frac=0.01))):
        ef = codec.init_state(g)
        t0 = time.time()
        sent, ef = codec.apply(g, ef)
        dt = (time.time() - t0) * 1e6
        rel = float(jnp.linalg.norm(sent["w"] - g["w"])
                    / jnp.linalg.norm(g["w"]))
        wire = codec.wire_bytes(N)
        ratio = (N * 4) / wire
        out[name] = {"rel_err_first_step": rel, "wire_ratio": ratio}
        print(f"{name:9s} wire {wire / 1e6:7.2f}MB vs f32 {N * 4 / 1e6:7.2f}MB "
              f"({ratio:5.1f}x less)  first-step rel-err {rel:.3f}")
        print(f"compression_bench,{name},{dt:.0f},wire_ratio={ratio:.1f}")
    return out


if __name__ == "__main__":
    main()
