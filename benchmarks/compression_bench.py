"""Gradient-compression + weight-calibration benchmark.

Two sections sharing the int8 calibration rule (repro.optim.compression):

  1. gradient compression for the DP all-reduce path (Int8Codec / TopKCodec
     with error feedback) — the collective-term lever for the roofline;
  2. per-channel weight calibration for the INT8 unlearning path
     (``q8_quantize``): round-trip quality and scale-table overhead on
     realistic weight shapes — the static cost the engine's
     ``precision="int8"`` family pays before any dampening.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.optim import Int8Codec, TopKCodec
from repro.optim.compression import q8_dequantize, q8_quantize

N = 1 << 20


def calib_bench() -> dict:
    """Per-channel int8 calibration quality on weight-like tensors: relative
    round-trip L2 error (per-channel vs per-TENSOR scales — the reason the
    engine carries a scale table, not one scalar) and the storage overhead
    of the table itself."""
    rng = np.random.default_rng(0)
    out = {}
    # [rows, cols] dense weight with per-row dynamic-range spread (x100
    # across rows) — the regime where one per-tensor scale starves most rows
    shapes = {"dense_1k": (1024, 1024), "ffn_4k": (1024, 4096)}
    print("# Per-channel int8 weight calibration (q8_quantize)")
    for name, (r, c) in shapes.items():
        row_scale = np.exp(rng.uniform(np.log(0.01), np.log(1.0), size=(r, 1)))
        w = jnp.asarray(rng.normal(size=(r, c)) * row_scale, jnp.float32)
        t0 = time.time()
        q, s = q8_quantize(w)
        rt = q8_dequantize(q, s)
        dt = (time.time() - t0) * 1e6
        rel_pc = float(jnp.linalg.norm(rt - w) / jnp.linalg.norm(w))
        q1, s1 = q8_quantize(w, lead_axes=0)      # one per-tensor scale
        rel_pt = float(jnp.linalg.norm(q8_dequantize(q1, s1) - w)
                       / jnp.linalg.norm(w))
        overhead = s.size * 4 / (q.size * 1)      # f32 table vs int8 codes
        out[name] = {"roundtrip_rel_err": rel_pc,
                     "per_tensor_rel_err": rel_pt,
                     "scale_overhead_frac": overhead}
        print(f"{name:9s} per-channel rel-err {rel_pc:.4f}  "
              f"per-tensor {rel_pt:.4f}  "
              f"table overhead {overhead * 100:.2f}%")
        print(f"compression_bench,calib_{name},{dt:.0f},"
              f"rel_err={rel_pc:.4f}")
    return out


def main() -> dict:
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=N), jnp.float32)}
    out = {}
    for name, codec in (("int8", Int8Codec(block=256)),
                        ("topk1pct", TopKCodec(frac=0.01))):
        ef = codec.init_state(g)
        t0 = time.time()
        sent, ef = codec.apply(g, ef)
        dt = (time.time() - t0) * 1e6
        rel = float(jnp.linalg.norm(sent["w"] - g["w"])
                    / jnp.linalg.norm(g["w"]))
        wire = codec.wire_bytes(N)
        ratio = (N * 4) / wire
        out[name] = {"rel_err_first_step": rel, "wire_ratio": ratio}
        print(f"{name:9s} wire {wire / 1e6:7.2f}MB vs f32 {N * 4 / 1e6:7.2f}MB "
              f"({ratio:5.1f}x less)  first-step rel-err {rel:.3f}")
        print(f"compression_bench,{name},{dt:.0f},wire_ratio={ratio:.1f}")
    out["calibration"] = calib_bench()
    return out


if __name__ == "__main__":
    main()
