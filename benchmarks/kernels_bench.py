"""Kernel-level benchmark (paper Fig. 5 / the FIMD & Dampening IP speedups).

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock is NOT the TPU story.  What we measure + derive instead:

  1. wall-clock of the fused jnp reference vs an UNFUSED op-by-op pipeline
     (the "run it on the core" baseline from the paper) — XLA-compiled, CPU;
  2. the modeled HBM-traffic ratio on TPU (bytes in/out per pass), which is
     what the IPs' speedups come from: FIMD fuses square+accumulate into the
     gradient stream (paper: 11.7x), Dampening fuses compare/beta/multiply
     (paper: 7.9x);
  3. the compiled unlearning ENGINE vs the legacy three-programs-per-layer
     sweep on the smoke LM config: steady-state (2nd..Nth forget request)
     wall-clock per request, recorded to BENCH_engine.json;
  4. the streamed global-Fisher REFRESH (one warm EMA fold of a retain
     microbatch) vs a from-scratch ``diag_fisher_streaming`` recompute —
     the amortization that keeps I_D fresh between drains — merged into
     BENCH_engine.json;
  5. the scanned whole-sweep MEGAPROGRAM (one compiled program per drain,
     on-device halting — repro.engine.sweep) vs the layerwise drive loop,
     single and coalesced, merged into BENCH_engine.json;
  6. the SERVING hot paths: coalesced multi-domain drain vs sequential
     per-domain sweeps (both through the scanned serving default), and
     chunked prefill vs the token-by-token decode walk, recorded to
     BENCH_serve.json (gated by benchmarks/check_regression.py in CI).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

N = 1 << 22  # 4M params

BENCH_ENGINE_PATH = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_engine.json")
BENCH_SERVE_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve.json")


def _merge_bench_json(path: str, out: dict) -> None:
    """Merge ``out`` into the JSON record at ``path`` (engine_bench and
    refresh_bench share BENCH_engine.json; neither may clobber the other)."""
    rec = {}
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
    rec.update(out)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def sweep_bench(arch: str = "gemma3-1b", reps: int = 3, n_domains: int = 3
                ) -> dict:
    """The scanned whole-sweep megaprogram vs the layerwise drive loop,
    steady state, single request AND coalesced drain, merged into
    BENCH_engine.json (gated by benchmarks/check_regression.py).

    Layerwise pays O(L) dispatches plus a host sync per halt checkpoint per
    sweep; scanned is ONE program launch per drain with on-device halting
    (repro.engine.sweep).  Both run through warm facades sharing hyper-
    parameters, so the ratio isolates the drive-loop cost."""
    from repro import configs
    from repro.api import ForgetRequest, UnlearnSpec, Unlearner
    from repro.core import adapters, fisher
    from repro.data import synthetic as syn
    from repro.models import lm as LM

    cfg = configs.get(arch).smoke
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=24,
                            n_per_domain=8, seed=0)
    toks, doms = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: LM.lm_loss(p, cfg, b[0], b[1], aux_weight=0.0)
    i_d = fisher.diag_fisher(loss_fn, params, (toks[:16, :-1], toks[:16, 1:]),
                             chunk_size=4)
    adapter = adapters.lm_adapter(cfg, 24)
    kw = dict(alpha=8.0, lam=1.0, tau=-1.0, checkpoint_every=2, chunk_size=4)
    unl = Unlearner(adapter, i_d, UnlearnSpec.for_mode("ficabu", **kw))
    scanned = unl.with_spec(UnlearnSpec.for_mode("ficabu", **kw,
                                                 sweep_mode="scanned"))
    fb = toks[:8]
    req = ForgetRequest(fb[:, :-1], fb[:, 1:])
    group = []
    for d in range(n_domains):
        f = toks[doms == d][:8]
        group.append(ForgetRequest(f[:, :-1], f[:, 1:], tag=d))

    # warm every family: layerwise fused/partial, scanned K=1 and K=n
    unl.forget(req, params=params)
    unl.forget_group(group, params=params)
    _, s_sc = scanned.forget(req, params=params)
    assert s_sc["engine"]["sweep_mode"] == "scanned", s_sc["engine"]

    t0 = time.time()
    for _ in range(reps):
        unl.forget(req, params=params)
    t_lw = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        _, s_sc = scanned.forget(req, params=params)
    t_sc = (time.time() - t0) / reps
    assert s_sc["engine"]["compiles"] == 0, "warm scanned sweep recompiled!"

    _, _, g_sc = scanned.forget_group(group, params=params)
    t0 = time.time()
    for _ in range(reps):
        unl.forget_group(group, params=params)
    t_lwg = (time.time() - t0) / (reps * n_domains)
    t0 = time.time()
    for _ in range(reps):
        _, _, g_sc = scanned.forget_group(group, params=params)
    t_scg = (time.time() - t0) / (reps * n_domains)
    assert g_sc["engine"]["sweep_mode"] == "scanned"
    assert g_sc["engine"]["compiles"] == 0, "warm scanned drain recompiled!"

    out = {
        "sweep_config": (f"{arch}-smoke full sweep, forget batch 8 x 24; "
                         f"coalesced drain over {n_domains} domains"),
        "sweep_layerwise_warm_s": t_lw,
        "sweep_scanned_warm_s": t_sc,
        "sweep_scanned_speedup": t_lw / t_sc,
        "sweep_coalesced_layerwise_per_domain_s": t_lwg,
        "sweep_coalesced_scanned_per_domain_s": t_scg,
        "sweep_coalesced_scanned_speedup": t_lwg / t_scg,
        "sweep_scanned_compiles_warm": int(s_sc["engine"]["compiles"]),
    }
    _merge_bench_json(BENCH_ENGINE_PATH, out)
    print("# Scanned whole-sweep megaprogram vs layerwise drive loop")
    print(f"single    layerwise {t_lw:8.4f}s  scanned {t_sc:8.4f}s  "
          f"speedup {out['sweep_scanned_speedup']:.2f}x")
    print(f"coalesced layerwise {t_lwg:8.4f}s/dom  scanned {t_scg:8.4f}s/dom  "
          f"speedup {out['sweep_coalesced_scanned_speedup']:.2f}x")
    print(f"kernels_bench,scanned_sweep,{t_sc * 1e6:.0f},"
          f"speedup={out['sweep_scanned_speedup']:.2f}")
    return out


def quant_bench(arch: str = "gemma3-1b", reps: int = 3) -> dict:
    """The INT8 program family vs the fp32 oracle, steady state, merged into
    BENCH_engine.json (gated by benchmarks/check_regression.py).

    On this CPU container the int8 path is a weight-only fake-quant
    SIMULATION (XLA has no int8 GEMM here), so warm wall-clock parity — not
    speedup — is the honest expectation; the quantisation win is reported as
    the byte-MAC / energy proxy (core.metrics.mac_proxy_table: int8 moves
    exactly 4x fewer operand bytes per MAC, ~20x less MAC energy).  What IS
    measured and gated:

      * zero warm recompiles in the int8_sweep family;
      * the engine really ran the int8 path (``precision`` tag — a silent
        fp32 fallback reproduces the oracle bit-exactly, so the gate also
        requires the param error to be NON-zero);
      * quantization-aware halting: with tau picked mid-trace from the fp32
        run, int8 halts at the SAME layer (tau compares on the dequantised
        partial accumulator — DESIGN.md §12);
      * the declared tolerance contract: max per-layer relative L2 error of
        the int8-swept params vs the fp32 oracle <= INT8_SWEEP_RTOL.
    """
    from repro import configs
    from repro.api import ForgetRequest, UnlearnSpec, Unlearner
    from repro.core import adapters, fisher, metrics
    from repro.data import synthetic as syn
    from repro.models import lm as LM
    from repro.optim.compression import INT8_SWEEP_RTOL, q8_fakequant_tree

    cfg = configs.get(arch).smoke
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=24,
                            n_per_domain=8, seed=0)
    toks, _ = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: LM.lm_loss(p, cfg, b[0], b[1], aux_weight=0.0)
    i_d = fisher.diag_fisher(loss_fn, params, (toks[:16, :-1], toks[:16, 1:]),
                             chunk_size=4)
    adapter = adapters.lm_adapter(cfg, 24)
    fb = toks[:8]
    req = ForgetRequest(fb[:, :-1], fb[:, 1:])
    kw = dict(alpha=8.0, lam=1.0, checkpoint_every=2, chunk_size=4,
              sweep_mode="scanned")

    # full-depth fp32 run picks a mid-trace tau so BOTH precisions must halt
    # early at the same checkpoint (the halt-parity gate)
    unl32 = Unlearner(adapter, i_d,
                      UnlearnSpec.for_mode("ficabu", tau=-1.0, **kw))
    _, s_full = unl32.forget(req, params=params)
    accs = [a for _, a in s_full["forget_acc_trace"]]
    tau = float(0.5 * (min(accs) + max(accs)))

    unl32 = unl32.with_spec(UnlearnSpec.for_mode("ficabu", tau=tau, **kw))
    unl8 = unl32.with_spec(UnlearnSpec.for_mode("ficabu", tau=tau,
                                                precision="int8", **kw))
    p32, s32 = unl32.forget(req, params=params)
    p8, s8 = unl8.forget(req, params=params)      # cold int8 (compiles)
    t0 = time.time()
    for _ in range(reps):
        p32, s32 = unl32.forget(req, params=params)
    t32 = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        p8, s8 = unl8.forget(req, params=params)
    t8 = (time.time() - t0) / reps
    assert s8["engine"]["precision"] == "int8", s8["engine"]
    assert s8["engine"]["compiles"] == 0, "warm int8 sweep recompiled!"

    # tolerance contract: compare against the fp32 oracle's DEPLOYED int8
    # state (fake-quant of the fp32-swept tree) so round-trip noise on
    # UNTOUCHED layers doesn't drown the dampening error being measured
    oracle = q8_fakequant_tree(p32)
    rel = []
    for a, b in zip(jax.tree_util.tree_leaves(oracle),
                    jax.tree_util.tree_leaves(p8)):
        d = float(jnp.linalg.norm((a - b).astype(jnp.float32).ravel()))
        n = float(jnp.linalg.norm(a.astype(jnp.float32).ravel()))
        rel.append(d / max(n, 1e-30))
    rel_err = max(rel)

    out = {
        "int8_config": (f"{arch}-smoke scanned sweep, forget batch 8 x 24, "
                        f"tau={tau:.4f} (fp32 mid-trace)"),
        "int8_fp32_sweep_warm_s": t32,
        "int8_sweep_warm_s": t8,
        "int8_vs_fp32_warm_ratio": t32 / t8,
        "int8_sweep_compiles_warm": int(s8["engine"]["compiles"]),
        "int8_engine_precision": s8["engine"]["precision"],
        "int8_halt_stop_l": int(s8["stopped_at_l"]),
        "int8_halt_parity": int(s8["stopped_at_l"] == s32["stopped_at_l"]),
        "int8_param_rel_err": rel_err,
        "int8_param_rtol_declared": INT8_SWEEP_RTOL,
    }
    out.update({f"int8_{k}" if not k.startswith(("fp32", "int8")) else k: v
                for k, v in metrics.mac_proxy_table(s8["macs"]).items()})
    _merge_bench_json(BENCH_ENGINE_PATH, out)
    print("# INT8 program family vs fp32 oracle (steady state)")
    print(f"sweep    fp32 {t32:8.4f}s  int8 {t8:8.4f}s  "
          f"(CPU simulates int8 — the win is the traffic proxy)")
    print(f"halt     fp32 stop_l={s32['stopped_at_l']}  "
          f"int8 stop_l={s8['stopped_at_l']}  "
          f"parity={bool(out['int8_halt_parity'])}")
    print(f"error    max per-layer rel L2 {rel_err:.4f}  "
          f"(declared rtol {INT8_SWEEP_RTOL})")
    print(f"proxy    byte-MAC reduction {out['int8_bytemac_reduction']:.1f}x  "
          f"energy reduction {out['int8_energy_reduction']:.1f}x")
    print(f"kernels_bench,int8_sweep,{t8 * 1e6:.0f},"
          f"rel_err={rel_err:.4f}")
    assert out["int8_halt_parity"] == 1, "int8 halted at a different layer!"
    assert 0.0 < rel_err <= INT8_SWEEP_RTOL, rel_err
    return out


def serve_bench(arch: str = "gemma3-1b", reps: int = 3, n_domains: int = 3
                ) -> dict:
    """The serving hot paths, steady state, recorded to BENCH_serve.json:

      1. coalesced K-domain drain (ONE ``forget_many`` launch through the
         scanned megaprogram — the serving default) vs K sequential
         single-domain sweeps through the same warm session;
      2. chunked prefill (``LM.prefill``, blocks of tokens per dispatch) vs
         the legacy token-by-token walk of the decode path.
    """
    from repro import configs
    from repro.api import UnlearnSpec, Unlearner
    from repro.core import adapters, fisher
    from repro.data import synthetic as syn
    from repro.models import lm as LM

    cfg = configs.get(arch).smoke
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=24,
                            n_per_domain=8, seed=0)
    toks, doms = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: LM.lm_loss(p, cfg, b[0], b[1], aux_weight=0.0)
    i_d = fisher.diag_fisher(loss_fn, params, (toks[:16, :-1], toks[:16, 1:]),
                             chunk_size=4)
    adapter = adapters.lm_adapter(cfg, 24)
    spec = UnlearnSpec.for_mode("ficabu", alpha=8.0, lam=1.0, tau=-1.0,
                                checkpoint_every=2, chunk_size=4,
                                sweep_mode="scanned")
    sets = []
    for d in range(n_domains):
        fb = toks[doms == d][:8]
        sets.append((fb[:, :-1], fb[:, 1:]))

    unl = Unlearner(adapter, i_d, spec)
    # warm both program families (single-set + split-edit group variants)
    unl.forget(sets[0], params=params)
    _, _, g_warm = unl.forget_group(sets, params=params)

    t0 = time.time()
    for _ in range(reps):
        for s in sets:
            unl.forget(s, params=params)
    t_seq = (time.time() - t0) / (reps * n_domains)

    t0 = time.time()
    for _ in range(reps):
        _, _, gs = unl.forget_group(sets, params=params)
    t_coal = (time.time() - t0) / (reps * n_domains)
    assert gs["engine"]["compiles"] == 0, "warm coalesced drain recompiled!"

    # --- chunked prefill vs token-by-token decode-path walk
    B, P, G = 8, 16, 8
    prompts = jnp.asarray(toks[:B, :P])
    decode_jit = jax.jit(lambda p, c, t, pos: LM.decode_step(p, cfg, t, c, pos))

    def tokenwise():
        cache = LM.init_cache(cfg, B, P + G)
        lg = None
        for i in range(P):
            lg, cache = decode_jit(params, cache, prompts[:, i:i + 1],
                                   jnp.int32(i))
        return lg

    def chunked():
        cache = LM.init_cache(cfg, B, P + G)
        lg, cache = LM.prefill(params, cfg, prompts, cache, block=8)
        return lg

    tokenwise()[0].block_until_ready()
    chunked()[0].block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        tokenwise()[0].block_until_ready()
    t_tok = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        chunked()[0].block_until_ready()
    t_chunk = (time.time() - t0) / reps

    out = {
        "config": (f"{arch}-smoke: {n_domains}-domain drain, forget batch "
                   f"8 x 24; prefill {B} x {P} tokens, block 8"),
        "sequential_warm_per_domain_s": t_seq,
        "coalesced_warm_per_domain_s": t_coal,
        "coalesce_speedup": t_seq / t_coal,
        "coalesced_compiles_warm": int(gs["engine"]["compiles"]),
        "prefill_tokenwise_s": t_tok,
        "prefill_chunked_s": t_chunk,
        "prefill_speedup": t_tok / t_chunk,
    }
    with open(BENCH_SERVE_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print("# Serving hot paths (steady state)")
    print(f"forget sweep  sequential {t_seq:8.4f}s/domain  "
          f"coalesced {t_coal:8.4f}s/domain  "
          f"speedup {out['coalesce_speedup']:.2f}x")
    print(f"prefill       tokenwise  {t_tok:8.4f}s        "
          f"chunked   {t_chunk:8.4f}s        "
          f"speedup {out['prefill_speedup']:.2f}x")
    print(f"kernels_bench,coalesced_sweep,{t_coal * 1e6:.0f},"
          f"speedup={out['coalesce_speedup']:.2f}")
    print(f"kernels_bench,chunked_prefill,{t_chunk * 1e6:.0f},"
          f"speedup={out['prefill_speedup']:.2f}")
    return out


def fleet_bench(arch: str = "gemma3-1b", reps: int = 3, n_tenants: int = 3
                ) -> dict:
    """Multi-tenant fleet compile economics (repro.fleet), merged into
    BENCH_serve.json:

      * ``fleet_shared_compile_ratio`` — total engine-program compiles for
        N same-family tenants over the N=1 run.  The shared ProgramCache
        contract pins this to exactly 1.0: tenant count must not multiply
        compiles (gated absolutely by check_regression.py);
      * ``fleet_warm_drain_compiles`` — compiles across a warm drain round
        on every tenant (must be 0: all tenants replay shared programs);
      * per-tenant warm drain latency, N=1 vs N=3 (machine-relative
        context for the compile counters).
    """
    from repro import configs
    from repro.api import UnlearnSpec
    from repro.data import synthetic as syn
    from repro.fleet import Fleet
    from repro.models import lm as LM

    cfg = configs.get(arch).smoke
    spec = UnlearnSpec.for_mode("ficabu", alpha=8.0, lam=1.0, tau=-1.0,
                                checkpoint_every=2, chunk_size=4,
                                sweep_mode="scanned")

    def run(n: int):
        fleet = Fleet()
        for k in range(n):
            dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4,
                                    seq_len=24, n_per_domain=8, seed=k)
            toks, doms = syn.make_lm_domains(dcfg)
            params = LM.init_lm(jax.random.PRNGKey(k), cfg)
            fleet.add_tenant(f"t{k}", cfg, toks, doms, 24, params=params,
                             spec=spec)
        for k in range(n):
            fleet.submit(f"t{k}", 1, due_batch=1)
        fleet.drain(1)  # cold round: the family compiles once, total
        cold_compiles = fleet.programs.compiles
        warm_compiles = 0
        t0 = time.time()
        for r in range(reps):
            for k in range(n):
                fleet.submit(f"t{k}", 1 + (r % 3), due_batch=2 + r)
            before = fleet.programs.compiles
            fleet.drain(2 + r)
            warm_compiles += fleet.programs.compiles - before
        t_warm = (time.time() - t0) / (reps * n)
        return cold_compiles, warm_compiles, t_warm

    n1_compiles, n1_warm, t1 = run(1)
    nN_compiles, nN_warm, tN = run(n_tenants)
    out = {
        "fleet_config": (f"{arch}-smoke x {n_tenants} same-family tenants, "
                         "single-domain drains, forget batch 8 x 24"),
        "fleet_compiles_n1": n1_compiles,
        "fleet_compiles_n3": nN_compiles,
        "fleet_shared_compile_ratio": nN_compiles / n1_compiles,
        "fleet_warm_drain_compiles": nN_warm + n1_warm,
        "fleet_warm_drain_per_tenant_s": tN,
        "fleet_single_warm_drain_per_tenant_s": t1,
    }
    _merge_bench_json(BENCH_SERVE_PATH, out)
    print("# Fleet compile economics (shared program cache)")
    print(f"compiles      n=1 {n1_compiles:3d}         n={n_tenants}  "
          f"{nN_compiles:3d}         ratio "
          f"{out['fleet_shared_compile_ratio']:.2f}x")
    print(f"warm drain    {tN:8.4f}s/tenant  (n=1: {t1:8.4f}s)  "
          f"compiles {out['fleet_warm_drain_compiles']}")
    print(f"kernels_bench,fleet_drain,{tN * 1e6:.0f},"
          f"ratio={out['fleet_shared_compile_ratio']:.2f}")
    assert out["fleet_shared_compile_ratio"] == 1.0, \
        "tenant count multiplied engine compiles!"
    assert out["fleet_warm_drain_compiles"] == 0, \
        "a warm fleet drain recompiled!"
    return out


def refresh_bench(arch: str = "gemma3-1b", reps: int = 5,
                  n_retain_batches: int = 4) -> dict:
    """Streamed I_D refresh vs a from-scratch global-Fisher recompute,
    steady state, merged into BENCH_engine.json (gated by
    benchmarks/check_regression.py).

    The serving loop's choice at a drain point is: fold ONE retain
    microbatch into the EMA (``FisherStream``, one cached program) or
    recompute I_D over the whole retain stream (``diag_fisher_streaming``,
    the SSD way).  Both warm — the ratio is the amortization the refresh
    subsystem buys."""
    from repro import configs
    from repro.core import fisher
    from repro.data import synthetic as syn
    from repro.engine import FisherStream
    from repro.models import lm as LM

    cfg = configs.get(arch).smoke
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=24,
                            n_per_domain=8, seed=0)
    toks, _ = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: LM.lm_loss(p, cfg, b[0], b[1], aux_weight=0.0)
    per = len(toks) // n_retain_batches
    retain = [(toks[i * per:(i + 1) * per, :-1],
               toks[i * per:(i + 1) * per, 1:])
              for i in range(n_retain_batches)]

    i_d = fisher.diag_fisher_streaming(loss_fn, params, retain, chunk_size=4)
    stream = FisherStream(loss_fn, i_d, decay=0.9, chunk_size=4)
    stream.fold(params, retain[0])  # warm the refresh program

    t0 = time.time()
    for r in range(reps):
        total = stream.fold(params, retain[r % n_retain_batches])
    jax.tree_util.tree_leaves(total)[0].block_until_ready()
    t_fold = (time.time() - t0) / reps

    t0 = time.time()
    for _ in range(reps):
        full = fisher.diag_fisher_streaming(loss_fn, params, retain,
                                            chunk_size=4)
    jax.tree_util.tree_leaves(full)[0].block_until_ready()
    t_full = (time.time() - t0) / reps

    out = {
        "refresh_config": (f"{arch}-smoke: EMA fold of 1 retain microbatch "
                           f"({per} x 24) vs full recompute over "
                           f"{n_retain_batches} batches"),
        "refresh_fold_warm_s": t_fold,
        "fisher_recompute_full_s": t_full,
        "refresh_vs_recompute_speedup": t_full / t_fold,
        "refresh_compiles_warm": 0 if stream.stats["refresh_hits"] >= reps
        else stream.stats["refresh_compiles"] - 1,
    }
    # merge into the engine record: the refresh program is the third
    # compiled family of the unlearning engine, gated from the same file
    _merge_bench_json(BENCH_ENGINE_PATH, out)
    print("# Streamed I_D refresh vs full recompute (steady state)")
    print(f"refresh  fold {t_fold:8.4f}s/microbatch   "
          f"recompute {t_full:8.4f}s   "
          f"speedup {out['refresh_vs_recompute_speedup']:.2f}x")
    print(f"kernels_bench,fisher_refresh,{t_fold * 1e6:.0f},"
          f"speedup={out['refresh_vs_recompute_speedup']:.2f}")
    assert out["refresh_compiles_warm"] == 0, "warm refresh recompiled!"
    return out


def engine_bench(arch: str = "gemma3-1b", reps: int = 2) -> dict:
    """Fused engine sweep vs legacy 3-program sweep, full-depth (tau=-1) on
    the smoke LM config. The engine's warm requests replay cached
    executables; the legacy driver re-traces its per-layer programs and
    rebuilds the per-checkpoint jits on every request."""
    from repro import configs
    from repro.api import ForgetRequest, UnlearnSpec, Unlearner
    from repro.core import adapters, cau, fisher
    from repro.data import synthetic as syn
    from repro.models import lm as LM

    cfg = configs.get(arch).smoke
    dcfg = syn.LMDataConfig(vocab=cfg.vocab, n_domains=4, seq_len=24,
                            n_per_domain=8, seed=0)
    toks, _ = syn.make_lm_domains(dcfg)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: LM.lm_loss(p, cfg, b[0], b[1], aux_weight=0.0)
    i_d = fisher.diag_fisher(loss_fn, params, (toks[:16, :-1], toks[:16, 1:]),
                             chunk_size=4)
    adapter = adapters.lm_adapter(cfg, 24)
    fb = toks[:8]
    spec = UnlearnSpec.for_mode("ficabu", alpha=8.0, lam=1.0, tau=-1.0,
                                checkpoint_every=2, chunk_size=4)
    ucfg = spec.to_config()  # the identical engine config, for the baseline
    req = ForgetRequest(fb[:, :-1], fb[:, 1:])

    def legacy():
        return cau.context_adaptive_unlearn_legacy(
            adapter, params, i_d, fb[:, :-1], fb[:, 1:], ucfg)

    t0 = time.time()
    legacy()
    t_legacy_cold = time.time() - t0
    t0 = time.time()
    for _ in range(reps):
        legacy()
    t_legacy_warm = (time.time() - t0) / reps

    unl = Unlearner(adapter, i_d, spec)
    t0 = time.time()
    _, s1 = unl.forget(req, params=params)
    t_engine_cold = time.time() - t0
    t0 = time.time()
    for _ in range(reps):
        _, sn = unl.forget(req, params=params)
    t_engine_warm = (time.time() - t0) / reps

    out = {
        "config": f"{arch}-smoke full sweep, forget batch 8 x 24",
        "legacy_cold_s": t_legacy_cold, "legacy_warm_s": t_legacy_warm,
        "engine_cold_s": t_engine_cold, "engine_warm_s": t_engine_warm,
        "speedup_warm": t_legacy_warm / t_engine_warm,
        "speedup_cold": t_legacy_cold / t_engine_cold,
        "engine_compiles_req1": s1["engine"]["compiles"],
        "engine_compiles_reqN": sn["engine"]["compiles"],
    }
    # merge, don't clobber: refresh_bench records into the same file
    _merge_bench_json(BENCH_ENGINE_PATH, out)
    print("# Engine vs legacy sweep (steady-state per forget request)")
    print(f"legacy   cold {t_legacy_cold:6.2f}s  warm {t_legacy_warm:6.2f}s")
    print(f"engine   cold {t_engine_cold:6.2f}s  warm {t_engine_warm:6.2f}s  "
          f"(compiles req1={out['engine_compiles_req1']}, "
          f"reqN={out['engine_compiles_reqN']})")
    print(f"kernels_bench,engine_sweep,{t_engine_warm * 1e6:.0f},"
          f"speedup={out['speedup_warm']:.2f}")
    assert out["engine_compiles_reqN"] == 0, "warm request recompiled!"
    return out


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6  # us


def main() -> dict:
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, N // 8)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    i_f = jnp.asarray(np.abs(rng.normal(size=(N,))) + 1e-6, jnp.float32)
    i_g = jnp.asarray(np.abs(rng.normal(size=(N,))) + 1e-6, jnp.float32)

    # --- FIMD: fused square+accumulate vs unfused (square -> store -> sum)
    fused_fimd = jax.jit(ref.fimd_ref)

    @jax.jit
    def unfused_fimd(gg):
        sq = gg * gg                      # materialised gradient-squares
        sq = sq + 0.0                     # defeat fusion boundary (copy)
        return jnp.sum(sq, axis=0)

    t_fused = _time(fused_fimd, g)
    t_unfused = _time(unfused_fimd, g)
    # TPU traffic model: unfused = read g + write g^2 + read g^2 + write out
    # vs fused read g + write out (out << g).
    fimd_traffic_ratio = (2 * N + 2 * N) / (N + N // 8)

    # --- Dampening: fused select/beta/multiply vs 3-pass pipeline
    fused_damp = jax.jit(lambda t, f, gl: ref.dampen_ref(t, f, gl, 10.0, 1.0))

    @jax.jit
    def unfused_damp(t, f, gl):
        sel = (f > 10.0 * gl) + 0.0       # pass 1: selection mask
        beta = jnp.minimum(1.0 * gl / jnp.maximum(f, 1e-30), 1.0) + 0.0  # pass 2
        return jnp.where(sel > 0, t * beta, t)  # pass 3

    t_fd = _time(fused_damp, th, i_f, i_g)
    t_ud = _time(unfused_damp, th, i_f, i_g)
    damp_traffic_ratio = (3 * N + 2 * N + 4 * N) / (4 * N)

    out = {
        "fimd_cpu_speedup": t_unfused / t_fused,
        "fimd_tpu_traffic_ratio": fimd_traffic_ratio,
        "dampen_cpu_speedup": t_ud / t_fd,
        "dampen_tpu_traffic_ratio": damp_traffic_ratio,
        "t_fimd_us": t_fused, "t_dampen_us": t_fd,
    }
    print("# Kernel IPs (paper Fig. 5): fusion wins")
    print(f"FIMD     fused {t_fused:9.0f}us  unfused {t_unfused:9.0f}us  "
          f"cpu-speedup {out['fimd_cpu_speedup']:.2f}x  "
          f"TPU traffic ratio {fimd_traffic_ratio:.2f}x")
    print(f"Dampen   fused {t_fd:9.0f}us  unfused {t_ud:9.0f}us  "
          f"cpu-speedup {out['dampen_cpu_speedup']:.2f}x  "
          f"TPU traffic ratio {damp_traffic_ratio:.2f}x")
    print(f"kernels_bench,fimd,{t_fused:.0f},speedup={out['fimd_cpu_speedup']:.2f}")
    print(f"kernels_bench,dampen,{t_fd:.0f},speedup={out['dampen_cpu_speedup']:.2f}")
    out["engine"] = engine_bench()
    out["refresh"] = refresh_bench()
    out["sweep"] = sweep_bench()
    out["quant"] = quant_bench()
    out["serve"] = serve_bench()
    out["fleet"] = fleet_bench()
    return out


if __name__ == "__main__":
    main()
