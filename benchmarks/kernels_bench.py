"""Kernel-level benchmark (paper Fig. 5 / the FIMD & Dampening IP speedups).

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock is NOT the TPU story.  What we measure + derive instead:

  1. wall-clock of the fused jnp reference vs an UNFUSED op-by-op pipeline
     (the "run it on the core" baseline from the paper) — XLA-compiled, CPU;
  2. the modeled HBM-traffic ratio on TPU (bytes in/out per pass), which is
     what the IPs' speedups come from: FIMD fuses square+accumulate into the
     gradient stream (paper: 11.7x), Dampening fuses compare/beta/multiply
     (paper: 7.9x).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

N = 1 << 22  # 4M params


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6  # us


def main() -> dict:
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, N // 8)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    i_f = jnp.asarray(np.abs(rng.normal(size=(N,))) + 1e-6, jnp.float32)
    i_g = jnp.asarray(np.abs(rng.normal(size=(N,))) + 1e-6, jnp.float32)

    # --- FIMD: fused square+accumulate vs unfused (square -> store -> sum)
    fused_fimd = jax.jit(ref.fimd_ref)

    @jax.jit
    def unfused_fimd(gg):
        sq = gg * gg                      # materialised gradient-squares
        sq = sq + 0.0                     # defeat fusion boundary (copy)
        return jnp.sum(sq, axis=0)

    t_fused = _time(fused_fimd, g)
    t_unfused = _time(unfused_fimd, g)
    # TPU traffic model: unfused = read g + write g^2 + read g^2 + write out
    # vs fused read g + write out (out << g).
    fimd_traffic_ratio = (2 * N + 2 * N) / (N + N // 8)

    # --- Dampening: fused select/beta/multiply vs 3-pass pipeline
    fused_damp = jax.jit(lambda t, f, gl: ref.dampen_ref(t, f, gl, 10.0, 1.0))

    @jax.jit
    def unfused_damp(t, f, gl):
        sel = (f > 10.0 * gl) + 0.0       # pass 1: selection mask
        beta = jnp.minimum(1.0 * gl / jnp.maximum(f, 1e-30), 1.0) + 0.0  # pass 2
        return jnp.where(sel > 0, t * beta, t)  # pass 3

    t_fd = _time(fused_damp, th, i_f, i_g)
    t_ud = _time(unfused_damp, th, i_f, i_g)
    damp_traffic_ratio = (3 * N + 2 * N + 4 * N) / (4 * N)

    out = {
        "fimd_cpu_speedup": t_unfused / t_fused,
        "fimd_tpu_traffic_ratio": fimd_traffic_ratio,
        "dampen_cpu_speedup": t_ud / t_fd,
        "dampen_tpu_traffic_ratio": damp_traffic_ratio,
        "t_fimd_us": t_fused, "t_dampen_us": t_fd,
    }
    print("# Kernel IPs (paper Fig. 5): fusion wins")
    print(f"FIMD     fused {t_fused:9.0f}us  unfused {t_unfused:9.0f}us  "
          f"cpu-speedup {out['fimd_cpu_speedup']:.2f}x  "
          f"TPU traffic ratio {fimd_traffic_ratio:.2f}x")
    print(f"Dampen   fused {t_fd:9.0f}us  unfused {t_ud:9.0f}us  "
          f"cpu-speedup {out['dampen_cpu_speedup']:.2f}x  "
          f"TPU traffic ratio {damp_traffic_ratio:.2f}x")
    print(f"kernels_bench,fimd,{t_fused:.0f},speedup={out['fimd_cpu_speedup']:.2f}")
    print(f"kernels_bench,dampen,{t_fd:.0f},speedup={out['dampen_cpu_speedup']:.2f}")
    return out


if __name__ == "__main__":
    main()
