"""Load-and-observability bench: seeded synthetic traffic, SLO gates.

Drives the ``repro.load`` harness against a 3-tenant fleet (shared program
cache, bounded queues, bursty overload) and records the SLO-facing numbers
in ``BENCH_load.json``:

  * ``load_slo_attainment``       fraction of declared SLO objectives met
                                  (gated to exactly 1.0);
  * ``load_queue_age_p99``        p99 forget-queue age in virtual batches,
                                  under deliberate burst overload;
  * ``load_steady_state_compiles``  program compiles after the warmup
                                  phase — the zero-warm-compile pin under
                                  load (every program family is compiled
                                  during warmup; steady state replays);
  * ``load_queue_bound_ok``       the bounded-queue invariant held at every
                                  observed depth (admission control works);
  * ``load_deterministic``        two runs of the seeded scenario produced
                                  identical event streams modulo wall-clock
                                  fields (the reproducibility contract);
  * ``load_reject_accounting_ok`` under ``admission="reject"`` the refused
                                  submits, the scheduler counters and the
                                  structured ``queue.reject`` events agree;
  * ``load_drains_per_sec``       wall-clock drain throughput
                                  (informational — machine dependent);
  * ``load_drain_throughput``     drained forget requests per virtual tick
                                  (deterministic).

A second, seeded CHAOS run (DESIGN.md §16) replays similar traffic with a
guarded fleet and a fault-injection plan (NaN forget batch, shadow-sweep
worker crash, publication-deadline miss) and gates the robustness
contract:

  * ``load_chaos_slo_attainment``   the chaos SLOs (drain floor, dead-
                                    letter budget, queue age) all hold on
                                    the non-faulted traffic (gated 1.0);
  * ``load_chaos_guard_violations`` tenants whose SERVED params contain a
                                    non-finite value after the run — a
                                    guard-violating publication (gated 0);
  * ``load_chaos_accounting_ok``    ``submitted == applied + pending +
                                    staged + dead`` for every tenant;
  * ``load_chaos_dead_letters``     retries-exhausted requests parked with
                                    full accounting (gated >= 1: the plan
                                    guarantees one NaN retry exhausts);
  * ``load_chaos_aborts``           guard/exception drain aborts (>= 2);
  * ``load_chaos_deterministic``    two chaos runs produce identical event
                                    fingerprints — faults and recovery are
                                    exactly as repeatable as clean runs.

Also writes the telemetry stream (``load_events.jsonl``) and the rendered
markdown report (``LOAD_REPORT.md``) — the artifacts CI uploads.

``benchmarks/check_regression.py`` ABS-gates the deterministic keys; the
wall-clock key is recorded but never gated.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.fleet import Fleet, FleetSpec, TenantSpec
from repro.load import ArrivalSpec, LoadHarness, LoadScenario, SLOSpec
from repro.load.harness import build_lm_tenant
from repro.obs import render, telemetry
from repro.robust import FaultSpec, GuardSpec

# The scenario under test: bursty overload against bounded queues.  Queue
# bound 2 with a burst factor of 6 guarantees overflow (defer-with-aging
# folds) while max_groups=2 forces cross-tenant deferrals — both
# backpressure paths exercise every drain point.
MAX_QUEUE = 2
SCENARIO = LoadScenario(
    ticks=10, warmup_ticks=6, deadline_slack=1,
    forget=ArrivalSpec(kind="bursty", rate=0.8, burst_factor=6.0,
                       duty=0.25, period=4, seed=3),
    generate=ArrivalSpec(kind="diurnal", rate=1.5, period=8, seed=5),
    domains=3, serve_generate=False, seed=11)

# Declared SLOs for the smoke deployment.  queue-age p99 bound: the burst
# period is 4 ticks and the group budget defers at most one round, so a
# healthy scheduler keeps even merged overflow work under ~6 batches old;
# sustained aging past that means starvation.
SLO = SLOSpec(max_queue_age_p99=6.0, max_queue_depth=MAX_QUEUE,
              min_drain_throughput=0.5, max_reject_fraction=0.0,
              max_steady_compiles=0)

EVENTS_PATH = "load_events.jsonl"
REPORT_PATH = "LOAD_REPORT.md"

# The chaos plan (DESIGN.md §16): one fault per failure class, each pinned
# to a tenant so the blast radius is known.  acme's NaN strikes twice —
# with a retry budget of 1 the second strike exhausts it, guaranteeing the
# dead-letter path runs; globex's worker crash and initech's deadline miss
# each recover within the retry/requeue budget.
CHAOS_GUARD = GuardSpec(finite=True, max_retries=1, backoff_batches=1)
CHAOS_FAULTS = (
    FaultSpec(site="nan_batch", tenant="acme", at=0, count=2),
    FaultSpec(site="worker_exc", tenant="globex", at=0, count=1),
    FaultSpec(site="deadline_miss", tenant="initech", at=0, count=1),
)
CHAOS_SCENARIO = LoadScenario(
    ticks=10, warmup_ticks=6, deadline_slack=1,
    forget=ArrivalSpec(kind="bursty", rate=0.8, burst_factor=6.0,
                       duty=0.25, period=4, seed=3),
    generate=ArrivalSpec(kind="diurnal", rate=1.5, period=8, seed=5),
    domains=3, serve_generate=False, seed=11, faults=CHAOS_FAULTS)

# Chaos SLOs bound the NON-faulted traffic: the drain floor and queue-age
# bound must survive the injected failures, and the dead-letter budget
# admits only the deliberately exhausted NaN group.  Queue depth and
# steady-compile pins are off — retries legitimately re-enter past the
# admission bound and may recompile at a new group width.
CHAOS_SLO = SLOSpec(max_queue_age_p99=10.0, min_drain_throughput=0.3,
                    max_dead_letter_fraction=0.5)


def _fleet_spec() -> FleetSpec:
    return FleetSpec(
        tenants=(TenantSpec(name="acme", arch="gemma3-1b", seed=0),
                 TenantSpec(name="globex", arch="gemma3-1b", seed=1,
                            weight=2.0),
                 TenantSpec(name="initech", arch="gemma3-1b", seed=2)),
        scheduling="fair", max_groups_per_drain=2,
        max_queue_per_tenant=MAX_QUEUE, admission="defer")


def _build_fleet(fspec: FleetSpec) -> Fleet:
    sc = SCENARIO
    return Fleet.from_spec(
        fspec, lambda t: build_lm_tenant(t, prompt_len=sc.prompt_len,
                                         gen_len=sc.gen_len))


def _run_once(path=None):
    fleet = _build_fleet(_fleet_spec())
    tel = telemetry.Telemetry(path=path,
                              clock=telemetry.VirtualClock(), keep=True)
    try:
        result = LoadHarness(fleet, SCENARIO).run(tel)
    finally:
        tel.close()
    return result, tel.events


def _queue_bound_ok(events, max_queue: int) -> bool:
    """The invariant: every observed queue depth respects the bound."""
    for ev in events:
        if ev.get("kind") in ("queue.enqueue", "queue.merge",
                              "queue.depth", "queue.reject"):
            d = ev.get("depth")
            if isinstance(d, int) and d > max_queue:
                return False
    return True


def _reject_scenario_ok() -> bool:
    """A short ``admission="reject"`` run: refused submits, scheduler
    counters and structured ``queue.reject`` events must all agree."""
    fspec = FleetSpec(
        tenants=(TenantSpec(name="solo", arch="gemma3-1b", seed=0),),
        scheduling="deadline", max_queue_per_tenant=1, admission="reject")
    fleet = _build_fleet(fspec)
    sc = LoadScenario(ticks=4, warmup_ticks=0, deadline_slack=2,
                      forget=ArrivalSpec(kind="poisson", rate=3.0, seed=9),
                      generate=ArrivalSpec(rate=0.0, seed=1),
                      domains=3, seed=13)
    res = LoadHarness(fleet, sc).run()
    snap = res["scheduler"]
    rejected_events = res["event_counts"].get("queue.reject", 0)
    total_rejects = sum(snap["rejects"].values())
    ok = (res["rejected_submits"] == total_rejects == rejected_events
          and total_rejects > 0
          and res["fleet"]["rejected"] == total_rejects)
    print(f"[load_bench] reject accounting: submits refused="
          f"{res['rejected_submits']} scheduler={total_rejects} "
          f"events={rejected_events} -> {'ok' if ok else 'MISMATCH'}")
    return ok


def _nonfinite_tenants(fleet: Fleet) -> int:
    """Tenants whose SERVED params hold a non-finite value — each one is a
    guard-violating publication (the NaN fault reached the live tree)."""
    bad = 0
    for name, rt in fleet.tenants.items():
        leaves = jax.tree_util.tree_leaves(rt.params)
        if any(not np.isfinite(np.asarray(x)).all() for x in leaves):
            print(f"[load_bench] CHAOS: tenant {name!r} serves non-finite "
                  "params — a guard-violating publication escaped")
            bad += 1
    return bad


def _run_chaos_once():
    fspec = FleetSpec(
        tenants=_fleet_spec().tenants,
        scheduling="fair", max_groups_per_drain=2,
        max_queue_per_tenant=MAX_QUEUE, admission="defer",
        guard=CHAOS_GUARD)
    fleet = _build_fleet(fspec)
    result = LoadHarness(fleet, CHAOS_SCENARIO).run()
    return result, fleet


def _chaos_record() -> dict:
    """Run the seeded chaos scenario twice; gate the robustness contract."""
    print("[load_bench] chaos run 1/2 (seeded fault plan)")
    res1, fleet1 = _run_chaos_once()
    print("[load_bench] chaos run 2/2 (determinism replay)")
    res2, _ = _run_chaos_once()
    deterministic = res1["fingerprint"] == res2["fingerprint"]

    fleet_sum = res1["fleet"]
    evaluation = CHAOS_SLO.evaluate(res1)
    accounting = res1["accounting"]
    acc_ok = bool(accounting) and all(a["ok"] for a in accounting.values())
    violations = _nonfinite_tenants(fleet1)

    for r in evaluation["objectives"]:
        print(f"[load_bench] chaos SLO {r['objective']}: "
              f"actual={r['actual']} target={r['target']} -> "
              f"{'ok' if r['ok'] else 'FAIL'}")
    for name, a in accounting.items():
        print(f"[load_bench] chaos accounting {name}: {a}")
    rec = {
        "load_chaos_slo_attainment": evaluation["attained"],
        "load_chaos_deterministic": int(deterministic),
        "load_chaos_accounting_ok": int(acc_ok),
        "load_chaos_guard_violations": violations,
        "load_chaos_dead_letters": fleet_sum["dead_letters"],
        "load_chaos_aborts": fleet_sum["aborts"],
        "load_chaos_requeues": fleet_sum["requeues"],
        "load_chaos_faults_fired": fleet_sum["faults"],
        "load_chaos_submitted": fleet_sum["submitted"],
        "load_chaos_drained_requests": fleet_sum["drained_requests"],
        "chaos_slo": CHAOS_SLO.to_dict(),
        "chaos_scenario": CHAOS_SCENARIO.to_dict(),
        "chaos_objectives": evaluation["objectives"],
        "chaos_accounting": accounting,
    }
    print(f"[load_bench] chaos attainment={evaluation['attained']:.2f} "
          f"deterministic={deterministic} accounting_ok={acc_ok} "
          f"guard_violations={violations} "
          f"dead_letters={fleet_sum['dead_letters']} "
          f"aborts={fleet_sum['aborts']}")
    return rec


def _chaos_report_section(rec: dict) -> str:
    lines = ["", "## Chaos scenario (seeded fault injection)", "",
             "| metric | value |", "|---|---|"]
    for k in ("load_chaos_slo_attainment", "load_chaos_deterministic",
              "load_chaos_accounting_ok", "load_chaos_guard_violations",
              "load_chaos_dead_letters", "load_chaos_aborts",
              "load_chaos_requeues", "load_chaos_faults_fired",
              "load_chaos_submitted", "load_chaos_drained_requests"):
        lines.append(f"| {k} | {rec[k]} |")
    lines.append("")
    lines.append("Fault plan: " + ", ".join(
        f"`{f.site}`@{f.tenant} (at={f.at}, count={f.count})"
        for f in CHAOS_FAULTS))
    return "\n".join(lines) + "\n"


def main() -> None:
    import time
    print("[load_bench] run 1/2 (writes the telemetry artifacts)")
    t0 = time.time()
    res1, events1 = _run_once(path=EVENTS_PATH)
    wall1 = time.time() - t0
    print("[load_bench] run 2/2 (determinism replay)")
    res2, events2 = _run_once()
    deterministic = (res1["fingerprint"] == res2["fingerprint"]
                     and telemetry.fingerprint(events1)
                     == telemetry.fingerprint(events2))

    fleet_sum = res1["fleet"]
    evaluation = SLO.evaluate(res1)
    bound_ok = _queue_bound_ok(events1, MAX_QUEUE)
    reject_ok = _reject_scenario_ok()
    chaos = _chaos_record()

    with open(REPORT_PATH, "w") as f:
        f.write(render(res1, evaluation) + "\n")
        f.write(_chaos_report_section(chaos))

    rec = {
        "load_slo_attainment": evaluation["attained"],
        "load_queue_age_p99": fleet_sum["queue_age"]["p99"],
        "load_queue_age_mean": fleet_sum["queue_age"]["mean"],
        "load_queue_depth_max": fleet_sum["queue_depth_max"],
        "load_steady_state_compiles": fleet_sum["steady_state_compiles"],
        "load_compiles": fleet_sum["compiles"],
        "load_program_hits": fleet_sum["program_hits"],
        "load_submitted": fleet_sum["submitted"],
        "load_merged": fleet_sum["merged"],
        "load_deferrals": fleet_sum["deferrals"],
        "load_drained_requests": fleet_sum["drained_requests"],
        "load_drain_throughput": fleet_sum["drain_throughput"],
        "load_drains_per_sec": (fleet_sum["drains"] / wall1
                                if wall1 > 0 else 0.0),
        "load_queue_bound_ok": int(bound_ok),
        "load_deterministic": int(deterministic),
        "load_reject_accounting_ok": int(reject_ok),
        "load_n_events": res1["n_events"],
        "slo": SLO.to_dict(),
        "scenario": SCENARIO.to_dict(),
        "objectives": evaluation["objectives"],
        **chaos,
    }
    with open("BENCH_load.json", "w") as f:
        json.dump(rec, f, indent=1)
    for r in evaluation["objectives"]:
        print(f"[load_bench] SLO {r['objective']}: actual={r['actual']} "
              f"target={r['target']} -> {'ok' if r['ok'] else 'FAIL'}")
    print(f"[load_bench] attainment={evaluation['attained']:.2f} "
          f"deterministic={deterministic} queue_bound_ok={bound_ok} "
          f"steady_compiles={fleet_sum['steady_state_compiles']} "
          f"queue_age_p99={fleet_sum['queue_age']['p99']} "
          f"events={res1['n_events']} -> BENCH_load.json, "
          f"{EVENTS_PATH}, {REPORT_PATH}")


if __name__ == "__main__":
    main()
