"""Paper Table I: Context-Adaptive Unlearning vs. baseline (no unlearning)
and SSD — forget/retain accuracy, MIA, and MACs (normalised to SSD = 100,
checkpoint overhead included), for ResNet and ViT."""
from __future__ import annotations

import time

from repro.api import ForgetRequest, UnlearnSpec, Unlearner
from repro.data import synthetic as syn

from . import common


def run(models=("resnet", "vit"), forget_classes=(2, 5)) -> list:
    rows = []
    for model in models:
        s = common.trained(model)
        alpha, lam = common.HPARAMS[model]
        tau = common.RANDOM_GUESS + 0.03
        # one warm facade per model: the SSD and CAU variants share the
        # compiled-program cache across every forget class
        unl_ssd = Unlearner(s["adapter"], s["I_D"],
                            UnlearnSpec.for_mode("ssd", alpha=alpha, lam=lam))
        unl_cau = unl_ssd.with_spec(UnlearnSpec.for_mode(
            "cau", alpha=alpha, lam=lam, tau=tau, checkpoint_every=2))
        for cls in forget_classes:
            splits = syn.split_forget_retain(s["x"], s["y"], cls)
            fx, fy = splits["forget"]
            base = common.eval_model(s, s["params"], cls)
            req = ForgetRequest(fx[:32], fy[:32], tag=cls)

            t0 = time.time()
            p_ssd, st_ssd = unl_ssd.forget(req, params=s["params"])
            t_ssd = time.time() - t0
            e_ssd = common.eval_model(s, p_ssd, cls)

            t0 = time.time()
            p_cau, st_cau = unl_cau.forget(req, params=s["params"])
            t_cau = time.time() - t0
            e_cau = common.eval_model(s, p_cau, cls)

            rows.append({
                "model": model, "class": cls,
                "baseline": base, "ssd": e_ssd, "cau": e_cau,
                "macs_ssd_pct": st_ssd["macs_vs_ssd_pct"],
                "macs_cau_pct": st_cau["macs_vs_ssd_pct"],
                "stop_l": st_cau["stopped_at_l"],
                "n_layers": s["adapter"].n_layers,
                "t_ssd_s": t_ssd, "t_cau_s": t_cau,
            })
    return rows


def main() -> list:
    rows = run()
    print("# Table I — CAU vs baseline vs SSD (percent)")
    print(f"{'model':8s} {'cls':>3s} | {'Dr base':>8s} {'Dr ssd':>7s} "
          f"{'Dr cau':>7s} | {'Df base':>8s} {'Df ssd':>7s} {'Df cau':>7s} | "
          f"{'MIA ssd':>7s} {'MIA cau':>7s} | {'MACs cau%':>9s} {'stop':>5s}")
    for r in rows:
        print(f"{r['model']:8s} {r['class']:3d} | "
              f"{r['baseline']['retain_acc']:8.2f} "
              f"{r['ssd']['retain_acc']:7.2f} {r['cau']['retain_acc']:7.2f} | "
              f"{r['baseline']['forget_acc']:8.2f} "
              f"{r['ssd']['forget_acc']:7.2f} {r['cau']['forget_acc']:7.2f} | "
              f"{r['ssd']['mia']:7.2f} {r['cau']['mia']:7.2f} | "
              f"{r['macs_cau_pct']:9.2f} "
              f"{r['stop_l']}/{r['n_layers']}")
    for r in rows:
        print(f"table1_cau,{r['model']}.{r['class']},"
              f"{r['t_cau_s'] * 1e6:.0f},macs_pct={r['macs_cau_pct']:.2f}")
    return rows


if __name__ == "__main__":
    main()
