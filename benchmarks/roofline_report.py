"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the JSON
records produced by ``python -m repro.launch.dryrun --all``."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "dryrun")
BENCH_ENGINE = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_engine.json")


def load(dirpath: str = DEFAULT_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | dom | compute_s | memory_s | collective_s | "
        "roofline frac | useful/HLO | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != "16x16":
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — skip | | | | | | |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        mem_gib = r["memory"].get("temp_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['dominant']} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['collective_s']:.3g} | {t['roofline_fraction']:.3f} "
            f"| {t['useful_flops_ratio']:.2f} | {mem_gib:.1f} |")
    return "\n".join(lines)


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | params | "
        "bytes/dev GiB | collectives (probe) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        st = r.get("status")
        if st == "skipped":
            reason = r.get("reason", "")[:46]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| skip: {reason}… | | | | |")
            continue
        if st != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| ERROR | | | | |")
            continue
        mem = r["memory"].get("temp_size_in_bytes", 0) / 2**30
        colls = ""
        if "collectives" in r:
            cnt = r["collectives"].get("by_op_counts_probe2", {})
            colls = " ".join(f"{k.split('-')[-1][:6]}:{v}"
                             for k, v in sorted(cnt.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('compile_full_s', 0):.1f} "
            f"| {r.get('n_params', 0) / 1e9:.2f}B | {mem:.1f} | {colls} |")
    return "\n".join(lines)


def summary(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") not in ("ok", "skipped")]
    single = [r for r in ok if r["mesh"] == "16x16" and "roofline" in r]
    worst = sorted(single, key=lambda r: r["roofline"]["roofline_fraction"])
    coll_bound = [r for r in single
                  if r["roofline"]["dominant"] == "collective"]
    return {"ok": len(ok), "skipped": len(skip), "errors": len(err),
            "worst_fraction": [(r["arch"], r["shape"],
                                round(r["roofline"]["roofline_fraction"], 4))
                               for r in worst[:6]],
            "collective_bound": [(r["arch"], r["shape"])
                                 for r in coll_bound[:8]]}


def int8_mac_table(path: str = BENCH_ENGINE) -> str:
    """The int8-vs-fp32 MAC/energy-proxy table for one unlearning sweep,
    from the keys kernels_bench.quant_bench records into BENCH_engine.json
    (per-MAC constants: core.metrics.MAC_OPERAND_BYTES / MAC_ENERGY_PJ)."""
    if not os.path.exists(path):
        return "(no BENCH_engine.json — run benchmarks/kernels_bench.py)"
    with open(path) as f:
        r = json.load(f)
    if "int8_macs" not in r:
        return "(BENCH_engine.json lacks int8 keys — run quant_bench)"
    lines = [
        "| precision | MACs | byte-MACs | MAC energy (J) | vs fp32 |",
        "|---|---|---|---|---|",
        f"| fp32 | {r['int8_macs']:.3g} | {r['fp32_byte_macs']:.3g} "
        f"| {r['fp32_mac_energy_j']:.3g} | 1.0x |",
        f"| int8 | {r['int8_macs']:.3g} | {r['int8_byte_macs']:.3g} "
        f"| {r['int8_mac_energy_j']:.3g} "
        f"| {r['int8_bytemac_reduction']:.1f}x bytes, "
        f"{r['int8_energy_reduction']:.1f}x energy |",
    ]
    return "\n".join(lines)


def main():
    recs = load()
    print(f"records: {len(recs)}")
    print(json.dumps(summary(recs), indent=1))
    print("\n## Roofline (single pod 16x16)\n")
    print(roofline_table(recs))
    print("\n## INT8 unlearning sweep: MAC / energy proxy\n")
    print(int8_mac_table())
    rows = [r for r in recs if r.get("status") == "ok"]
    print(f"roofline_report,cells,{len(rows)},errors="
          f"{summary(recs)['errors']}")


if __name__ == "__main__":
    main()
