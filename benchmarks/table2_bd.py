"""Paper Table II: Balanced Dampening vs. SSD — Delta-Dr and RPR (Eq. 7),
with c_m auto-derived from the SSD selection distribution (paper §III-B)."""
from __future__ import annotations

import time

from repro.api import ForgetRequest, UnlearnSpec, Unlearner
from repro.core import metrics
from repro.core.ficabu import auto_midpoint
from repro.data import synthetic as syn

from . import common


def run(models=("resnet", "vit"), forget_classes=(2, 5)) -> list:
    rows = []
    for model in models:
        s = common.trained(model)
        alpha, lam = common.HPARAMS[model]
        unl_ssd = Unlearner(s["adapter"], s["I_D"],
                            UnlearnSpec.for_mode("ssd", alpha=alpha, lam=lam))
        for cls in forget_classes:
            splits = syn.split_forget_retain(s["x"], s["y"], cls)
            fx, fy = splits["forget"]
            base = common.eval_model(s, s["params"], cls)
            req = ForgetRequest(fx[:32], fy[:32], tag=cls)

            p_ssd, st_ssd = unl_ssd.forget(req, params=s["params"])
            e_ssd = common.eval_model(s, p_ssd, cls)
            c_m = auto_midpoint(st_ssd)

            unl_bd = unl_ssd.with_spec(UnlearnSpec.for_mode(
                "bd", alpha=alpha, lam=lam, b_r=common.B_R[model], c_m=c_m))
            t0 = time.time()
            p_bd, st_bd = unl_bd.forget(req, params=s["params"])
            t_bd = time.time() - t0
            e_bd = common.eval_model(s, p_bd, cls)

            d_ssd = base["retain_acc"] - e_ssd["retain_acc"]
            d_bd = base["retain_acc"] - e_bd["retain_acc"]
            rows.append({
                "model": model, "class": cls, "c_m": c_m,
                "baseline": base, "ssd": e_ssd, "bd": e_bd,
                "delta_dr_ssd": d_ssd, "delta_dr_bd": d_bd,
                "rpr": metrics.rpr(d_bd, d_ssd),
                "sel_ssd": st_ssd["selected_per_layer"],
                "sel_bd": st_bd["selected_per_layer"],
                "t_bd_s": t_bd,
            })
    return rows


def main() -> list:
    rows = run()
    print("# Table II — Balanced Dampening vs SSD (percent)")
    print(f"{'model':8s} {'cls':>3s} | {'Dr ssd':>7s} {'Dr bd':>7s} | "
          f"{'Df ssd':>7s} {'Df bd':>7s} | {'dDr ssd':>8s} {'dDr bd':>7s} "
          f"{'RPR':>7s} | {'c_m':>5s}")
    for r in rows:
        print(f"{r['model']:8s} {r['class']:3d} | "
              f"{r['ssd']['retain_acc']:7.2f} {r['bd']['retain_acc']:7.2f} | "
              f"{r['ssd']['forget_acc']:7.2f} {r['bd']['forget_acc']:7.2f} | "
              f"{r['delta_dr_ssd']:8.2f} {r['delta_dr_bd']:7.2f} "
              f"{r['rpr']:7.2f} | {r['c_m']:5.1f}")
    for r in rows:
        print(f"table2_bd,{r['model']}.{r['class']},"
              f"{r['t_bd_s'] * 1e6:.0f},rpr={r['rpr']:.2f}")
    return rows


if __name__ == "__main__":
    main()
