"""CI bench regression gate.

Compares the freshly measured smoke-bench JSONs against the committed
baselines and fails (exit 1) when a warm serving path regressed by more
than ``--max-ratio`` (default 2x).

Absolute wall-clock is not comparable across machines (a CI runner vs the
box that produced the committed baseline differ severalfold), so each gated
warm-path time is NORMALISED by a reference measured in the SAME run and
recorded in the same JSON — the legacy sweep for the engine, the
sequential/tokenwise paths for serving. The gate then compares the
fresh normalised cost against the committed normalised cost: a genuine
engine or serving regression (a lost program cache, a de-coalesced drain,
prefill falling back to per-token dispatch) moves the normalised number by
10-100x; machine speed cancels out.

    python -m benchmarks.check_regression \
        --baseline-dir /tmp/bench-baseline --fresh-dir .

A missing baseline file passes with a note (first run on a branch that
introduces a new benchmark); a missing FRESH file fails — the smoke bench
must produce it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# file -> (warm-path key, same-run reference key) pairs; the gated metric is
# warm/reference, i.e. the warm path's cost relative to its unoptimised
# sibling measured on the same machine in the same process.
GATED = {
    "BENCH_engine.json": (
        ("engine_warm_s", "legacy_warm_s"),
        # streamed I_D refresh: one warm EMA fold vs the from-scratch
        # diag_fisher_streaming recompute measured in the same run — a lost
        # refresh-program cache shows up as this ratio collapsing toward 1
        ("refresh_fold_warm_s", "fisher_recompute_full_s"),
        # scanned whole-sweep megaprogram vs the layerwise drive loop in
        # the same run — a fallback to layerwise (or a lost sweep-program
        # cache) pushes this ratio toward/above 1
        ("sweep_scanned_warm_s", "sweep_layerwise_warm_s"),
    ),
    "BENCH_serve.json": (
        ("coalesced_warm_per_domain_s", "sequential_warm_per_domain_s"),
        ("prefill_chunked_s", "prefill_tokenwise_s"),
    ),
    # no normalised pairs (every load gate is machine-independent, see
    # ABS_GATES) — the empty entry still makes a MISSING fresh
    # BENCH_load.json fail, so the load bench cannot silently not run
    "BENCH_load.json": (),
}

# The int8 path's declared tolerance contract, hardcoded HERE on purpose so a
# drive-by loosening of repro.optim.compression.INT8_SWEEP_RTOL cannot move
# this gate silently — tests/test_quant.py cross-asserts the two are equal.
INT8_SWEEP_RTOL_GATE = 0.10

# Machine-independent absolute gates on the FRESH record (no baseline
# needed): (key, lo, hi) with lo <= value <= hi required.  The int8 keys
# catch the failure modes wall-clock can't: a silent fp32 fallback
# reproduces the oracle exactly (param error 0 < the 1e-7 floor), a lost
# program cache recompiles warm, and a quantisation-unaware tau compare
# halts at a different layer than the fp32 oracle.
ABS_GATES = {
    "BENCH_engine.json": (
        ("int8_bytemac_reduction", 4.0, float("inf")),
        ("int8_sweep_compiles_warm", 0, 0),
        ("int8_halt_parity", 1, 1),
        ("int8_param_rel_err", 1e-7, INT8_SWEEP_RTOL_GATE),
    ),
    # the fleet's shared-program-cache contract (repro.fleet): N
    # same-family tenants compile exactly the N=1 program set (ratio
    # pinned to 1.0 — tenant count must not multiply compiles), and a
    # warm drain round across every tenant replays with zero compiles.
    # The serve_stream_* keys are the zero-downtime contract (DESIGN.md
    # §15, benchmarks/serve_latency_bench.py): decode-step p99 with two
    # mid-stream shadow drains within 20% of drain-free — both measured
    # in the SAME run, so machine speed cancels — plus every fired drain
    # published atomically, ONE decode program signature across
    # publications, and a run-to-run identical engine event stream.
    "BENCH_serve.json": (
        ("fleet_shared_compile_ratio", 1.0, 1.0),
        ("fleet_warm_drain_compiles", 0, 0),
        ("serve_stream_p99_ratio", 0.0, 1.2),
        ("serve_stream_publications", 2, 2),
        ("serve_stream_decode_signatures", 1, 1),
        ("serve_stream_deterministic", 1, 1),
    ),
    # the load/observability SLO contract (repro.load + repro.obs): every
    # declared objective met, zero program compiles in steady state (warm
    # fleet under load replays only), the bounded queue held at every
    # observed depth, two seeded runs fingerprint-identical, and the
    # reject-policy accounting consistent across submits/counters/events.
    # queue-age p99 is virtual-clock batches — machine independent.
    "BENCH_load.json": (
        ("load_slo_attainment", 1.0, 1.0),
        ("load_steady_state_compiles", 0, 0),
        ("load_queue_bound_ok", 1, 1),
        ("load_deterministic", 1, 1),
        ("load_reject_accounting_ok", 1, 1),
        ("load_queue_age_p99", 0.0, 6.0),
        # the chaos contract (DESIGN.md §16): under the seeded fault plan
        # the non-faulted SLOs still attain, no guard-violating tree is
        # ever published, the accounting invariant (submitted == applied +
        # pending + staged + dead) holds for every tenant, at least one
        # request exhausts its retries into the dead-letter queue (the
        # plan guarantees it) with at least one drain abort on the way,
        # and two chaos runs are fingerprint-identical — fault injection
        # is exactly as repeatable as clean traffic.
        ("load_chaos_slo_attainment", 1.0, 1.0),
        ("load_chaos_deterministic", 1, 1),
        ("load_chaos_accounting_ok", 1, 1),
        ("load_chaos_guard_violations", 0, 0),
        ("load_chaos_dead_letters", 1, 1_000_000),
        ("load_chaos_aborts", 1, 1_000_000),
    ),
}


def _norm(rec: dict, warm_key: str, ref_key: str):
    if warm_key not in rec or ref_key not in rec:
        return None
    ref = float(rec[ref_key])
    return float(rec[warm_key]) / ref if ref > 0 else float("inf")


def check(baseline_dir: str, fresh_dir: str, max_ratio: float) -> int:
    failures = 0
    for fname, pairs in GATED.items():
        base_path = os.path.join(baseline_dir, fname)
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(fresh_path):
            print(f"[check_regression] FAIL {fname}: fresh run did not "
                  f"produce it (looked in {fresh_dir})")
            failures += 1
            continue
        if not os.path.exists(base_path):
            print(f"[check_regression] note: no committed baseline {fname}; "
                  "skipping (new benchmark)")
            continue
        with open(base_path) as f:
            base = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        for warm_key, ref_key in pairs:
            b = _norm(base, warm_key, ref_key)
            if b is None:
                print(f"[check_regression] note: baseline {fname} lacks "
                      f"{warm_key}/{ref_key}; skipping key")
                continue
            fr = _norm(fresh, warm_key, ref_key)
            if fr is None:
                print(f"[check_regression] FAIL {fname}: fresh run lacks "
                      f"{warm_key}/{ref_key}")
                failures += 1
                continue
            ratio = fr / b if b > 0 else float("inf")
            verdict = "ok" if ratio <= max_ratio else "FAIL"
            print(f"[check_regression] {verdict} {fname}:{warm_key} "
                  f"normalised by {ref_key}: baseline={b:.4f} fresh={fr:.4f} "
                  f"ratio={ratio:.2f} (max {max_ratio:.1f})")
            if ratio > max_ratio:
                failures += 1
    for fname, gates in ABS_GATES.items():
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(fresh_path):
            continue  # absence already failed above for gated files
        with open(fresh_path) as f:
            fresh = json.load(f)
        for key, lo, hi in gates:
            if key not in fresh:
                print(f"[check_regression] FAIL {fname}: fresh run lacks "
                      f"{key} (int8 bench did not run?)")
                failures += 1
                continue
            v = float(fresh[key])
            ok = lo <= v <= hi
            print(f"[check_regression] {'ok' if ok else 'FAIL'} "
                  f"{fname}:{key} = {v:.6g} (required [{lo:.6g}, {hi:.6g}])")
            if not ok:
                failures += 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory the smoke benchmarks wrote into")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when fresh/baseline normalised cost "
                         "exceeds this")
    args = ap.parse_args(argv)
    failures = check(args.baseline_dir, args.fresh_dir, args.max_ratio)
    if failures:
        print(f"[check_regression] {failures} gated metric(s) regressed")
        return 1
    print("[check_regression] all gated metrics within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
