"""Shared benchmark setup: pre-trained ResNet-18(small) and ViT(small) on the
CIFAR-20-like synthetic dataset, plus global Fisher importance — computed
once per process and reused by every table benchmark.

Scale note: the paper trains full ResNet-18/ViT on CIFAR-20; this container
is CPU-only, so the faithful pipeline runs at reduced width/classes (the
unlearning *mechanics* — selection geometry, early-stop depth, RPR sign —
are scale-free; see EXPERIMENTS.md for the claim-by-claim mapping).
"""
from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapters, fisher, metrics
from repro.data import synthetic as syn
from repro.models import vision as V
from repro.optim import AdamWConfig, init_adamw, make_train_step

N_CLASSES = 8
RANDOM_GUESS = 1.0 / N_CLASSES

# per-model SSD hyperparameters (the paper likewise uses (10,1) for RN and
# (25,1) for ViT on CIFAR-20; our reduced ViT calibrates to (5,1))
HPARAMS = {"resnet": (10.0, 1.0), "vit": (5.0, 1.0)}
# Balanced-Dampening front-end bound per model (paper uses b_r=10 for the
# full-size models; the reduced ViT calibrates to b_r=5 — see EXPERIMENTS.md)
B_R = {"resnet": 10.0, "vit": 5.0}


@functools.lru_cache(maxsize=None)
def classification_data():
    dcfg = syn.ClsDataConfig(n_classes=N_CLASSES, n_per_class=32,
                             img_size=24, seed=0)
    return syn.make_classification(dcfg)


def _train(model: str, steps: int = 160):
    x, y = classification_data()
    key = jax.random.PRNGKey(0)
    if model == "resnet":
        cfg = V.ResNetConfig(name="rn18-small", width=12, n_classes=N_CLASSES,
                             img_size=24)
        params = V.init_resnet(key, cfg)
        fwd = lambda p, im: V.resnet_forward(p, cfg, im)
        adapter = adapters.resnet_adapter(cfg)
    else:
        cfg = V.ViTConfig(name="vit-small", n_layers=6, d_model=48,
                          n_heads=2, d_ff=96, n_classes=N_CLASSES,
                          img_size=24, patch=4)
        params = V.init_vit(key, cfg)
        fwd = lambda p, im: V.vit_forward(p, cfg, im)
        adapter = adapters.vit_adapter(cfg)

    loss_fn = lambda p, b: V.cls_loss(fwd(p, b[0]), b[1])
    ocfg = AdamWConfig(lr=1.5e-3, total_steps=steps, warmup_steps=20,
                       weight_decay=1e-4)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    opt = init_adamw(ocfg, params)
    bt = syn.Batches((x, y), batch=64, seed=1)  # 4 epochs over 256 samples
    for _ in range(steps):
        bx, by = next(bt)
        params, opt, _ = step(params, opt, (bx, by))

    batches = [(x[i:i + 64], y[i:i + 64]) for i in range(0, len(y) - 63, 64)][:3]
    I_D = fisher.diag_fisher_streaming(loss_fn, params, batches, chunk_size=8)
    return {"cfg": cfg, "params": params, "fwd": fwd, "loss_fn": loss_fn,
            "adapter": adapter, "I_D": I_D, "x": x, "y": y}


@functools.lru_cache(maxsize=None)
def trained(model: str) -> Dict:
    t0 = time.time()
    out = _train(model)
    out["train_s"] = time.time() - t0
    return out


def eval_model(setting, params, forget_class: int):
    x, y = setting["x"], setting["y"]
    splits = syn.split_forget_retain(x, y, forget_class=forget_class)
    fx, fy = splits["forget"]
    rx, ry = splits["retain"]
    hx, hy = splits["heldout"]
    lg_f = setting["fwd"](params, fx)
    lg_r = setting["fwd"](params, rx)
    lg_h = setting["fwd"](params, hx)
    mia = metrics.mia_accuracy(
        np.asarray(metrics.per_sample_nll(lg_f, jnp.asarray(fy))),
        np.asarray(metrics.per_sample_nll(lg_h, jnp.asarray(hy))))
    return {
        "forget_acc": float(metrics.accuracy(lg_f, jnp.asarray(fy))) * 100,
        "retain_acc": float(metrics.accuracy(lg_r, jnp.asarray(ry))) * 100,
        "mia": mia * 100,
    }
