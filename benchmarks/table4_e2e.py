"""Paper Table IV: full FiCABU (CAU + BD) on the INT8-quantised model —
retain/forget, MACs vs SSD, RPR, and modeled energy saving (ES).

Energy model (45nm numbers are not measurable here): unlearning is
MAC-dominated on the edge processor (GEMM+DDR = 88% of power in Table III),
so modeled ES = 1 - (MACs_ficabu / MACs_ssd) scaled by the non-compute
floor (the paper's residual: control + leakage ~ 2% of run energy).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import ForgetRequest, UnlearnSpec, Unlearner
from repro.core import metrics
from repro.data import synthetic as syn
from repro.models.module import map_with_paths

from . import common

NON_COMPUTE_FLOOR = 0.02


def _quantize(setting):
    scales = {}

    def quant(path, x):
        if x.ndim >= 2:
            s = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-12
            scales[path] = s
            return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
        return x

    q = map_with_paths(quant, setting["params"])

    def dequant(tree):
        return map_with_paths(
            lambda path, x: x.astype(jnp.float32) * scales[path]
            if path in scales else x, tree)

    return q, dequant


def run(forget_class: int = 2) -> dict:
    s = common.trained("resnet")
    qtree, dequant = _quantize(s)
    deq_params = dequant(qtree)

    base = common.eval_model(s, deq_params, forget_class)
    splits = syn.split_forget_retain(s["x"], s["y"], forget_class)
    fx, fy = splits["forget"]
    tau = common.RANDOM_GUESS + 0.03

    # one warm facade serves both the SSD baseline and FiCABU; both sweeps
    # run the kernel dampening path (bit-equal to the jnp path, see
    # test_kernel_path_matches_jnp_path) so the FiCABU sweep reuses every
    # per-layer program the SSD sweep compiled.
    unl_ssd = Unlearner(s["adapter"], s["I_D"], UnlearnSpec.for_mode(
        "ssd", alpha=10.0, lam=1.0, use_kernel=True))
    unl_fic = unl_ssd.with_spec(UnlearnSpec.for_mode(
        "ficabu", alpha=10.0, lam=1.0, tau=tau, checkpoint_every=2,
        b_r=10.0, use_kernel=True))
    req = ForgetRequest(fx[:32], fy[:32], tag=forget_class)

    # SSD on the INT8-deployed model (baseline processor)
    p_ssd, st_ssd = unl_ssd.forget(req, params=deq_params)
    e_ssd = common.eval_model(s, p_ssd, forget_class)

    # FiCABU (CAU + BD, kernel dampening path) on the same model
    t0 = time.time()
    p_fic, st_fic = unl_fic.forget(req, params=deq_params)
    t_fic = time.time() - t0
    e_fic = common.eval_model(s, p_fic, forget_class)

    d_ssd = base["retain_acc"] - e_ssd["retain_acc"]
    d_fic = base["retain_acc"] - e_fic["retain_acc"]
    macs_pct = 100.0 * st_fic["macs"] / max(st_ssd["macs"], 1)
    es = (1.0 - NON_COMPUTE_FLOOR) * (1.0 - macs_pct / 100.0) * 100.0

    # Coalesced two-request drain (regulation-driven deletions batch): both
    # classes forgotten in ONE back-end-first sweep through the same warm
    # session, per-class halting preserved; MACs compared against running
    # SSD once per request (the baseline processor's cost for the burst).
    forget2 = (forget_class + 3) % common.N_CLASSES
    splits2 = syn.split_forget_retain(s["x"], s["y"], forget2)
    f2x, f2y = splits2["forget"]
    t0 = time.time()
    p_co, st_k, gstats = unl_fic.forget_group(
        [req, ForgetRequest(f2x[:32], f2y[:32], tag=forget2)],
        params=deq_params)
    t_co = time.time() - t0
    e_co1 = common.eval_model(s, p_co, forget_class)
    e_co2 = common.eval_model(s, p_co, forget2)
    coalesced = {
        "classes": [forget_class, forget2],
        "sweeps": gstats["sweeps"],
        "stopped_at_l": gstats["stopped_at_l"],
        "forget_acc": [e_co1["forget_acc"], e_co2["forget_acc"]],
        "retain_acc": e_co2["retain_acc"],
        "macs_pct_vs_2xssd": 100.0 * gstats["macs"] / max(2 * st_ssd["macs"], 1),
        "engine_compiles": gstats["engine"]["compiles"],
        "t_s": t_co,
    }
    return {
        "baseline": base, "ssd": e_ssd, "ficabu": e_fic,
        "macs_pct": macs_pct,
        "rpr": metrics.rpr(d_fic, d_ssd),
        "energy_saving_pct": es,
        "t_ficabu_s": t_fic,
        "coalesced": coalesced,
    }


def main() -> dict:
    r = run()
    print("# Table IV — FiCABU on the INT8 deployment (percent)")
    print(f"{'metric':12s} {'Baseline':>9s} {'SSD':>8s} {'FiCABU':>8s}")
    for kacc, label in (("retain_acc", "Dr"), ("forget_acc", "Df"),
                        ("mia", "MIA")):
        print(f"{label:12s} {r['baseline'][kacc]:9.2f} "
              f"{r['ssd'][kacc]:8.2f} {r['ficabu'][kacc]:8.2f}")
    print(f"{'MACs %':12s} {'-':>9s} {100.0:8.2f} {r['macs_pct']:8.2f}")
    print(f"{'RPR':12s} {'-':>9s} {'-':>8s} {r['rpr']:8.2f}")
    print(f"{'ES (model)':12s} {'-':>9s} {'-':>8s} "
          f"{r['energy_saving_pct']:8.2f}")
    co = r["coalesced"]
    print(f"# Coalesced burst: classes {co['classes']} in "
          f"{co['sweeps']} sweep(s)")
    print(f"{'Df (both)':12s} {co['forget_acc'][0]:9.2f} "
          f"{co['forget_acc'][1]:8.2f}")
    print(f"{'Dr':12s} {co['retain_acc']:9.2f}")
    print(f"{'stop_l':12s} {str(co['stopped_at_l']):>9s}")
    print(f"{'MACs %2xSSD':12s} {co['macs_pct_vs_2xssd']:9.2f}")
    print(f"table4_e2e,coalesced_burst,{co['t_s'] * 1e6:.0f},"
          f"macs_vs_2xssd={co['macs_pct_vs_2xssd']:.2f}")
    return r


if __name__ == "__main__":
    main()
